"""Slot-based continuous-batching serving engine on the sequential decode
path (per-slot positions — every request at its own offset in its own ring
cache row).

Token-level scheduling: at each engine step every ACTIVE slot advances one
token — prompt tokens are fed (prefill-by-decode) until exhausted, then
sampled continuations; finished slots retire and are refilled from the
queue. This is the single-host engine; the pipeline-parallel variant uses
the same per-slot-position decode attention through ``make_serve_step``.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as model_lib


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    """Greedy (or temperature) continuous-batching generation."""

    def __init__(self, cfg: ModelConfig, params, *, max_slots: int = 4,
                 cache_len: int = 64, eos_id: Optional[int] = None,
                 temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.cache_len = cache_len
        self.eos_id = eos_id
        self.temperature = temperature
        self._key = jax.random.PRNGKey(seed)
        self._queue: deque[Request] = deque()
        self._slots: list[Optional[Request]] = [None] * max_slots
        self._pos = np.zeros(max_slots, np.int32)      # next position to write
        self._next_tok = np.zeros(max_slots, np.int32)
        self._uid = 0
        self.caches = model_lib.init_caches(cfg, batch=max_slots,
                                            cache_len=cache_len,
                                            dtype=jnp.float32)
        self._step_fn = jax.jit(self._decode_step)

    # ------------------------------- api --------------------------------
    def submit(self, prompt: list[int], max_new_tokens: int = 16) -> int:
        self._uid += 1
        self._queue.append(Request(self._uid, list(prompt), max_new_tokens))
        return self._uid

    def run_until_drained(self, max_steps: int = 10_000) -> dict[int, list[int]]:
        out: dict[int, list[int]] = {}
        for _ in range(max_steps):
            finished = self.step()
            for r in finished:
                out[r.uid] = r.generated
            if not self._queue and all(s is None for s in self._slots):
                break
        return out

    # ----------------------------- internals ----------------------------
    def _decode_step(self, params, caches, tokens, pos):
        logits, new_caches = model_lib.sequential_decode_step(
            params, self.cfg, tokens[:, None], caches, pos)
        return logits[:, 0], new_caches

    def _reset_slot_cache(self, i: int):
        """Zero slot i's rows in every cache leaf (fresh request)."""
        def zero_row(a):
            return a.at[:, i].set(jnp.zeros_like(a[:, i]))
        self.caches = [jax.tree.map(zero_row, c) for c in self.caches]

    def step(self) -> list[Request]:
        # admit queued requests into free slots
        for i in range(self.max_slots):
            if self._slots[i] is None and self._queue:
                r = self._queue.popleft()
                self._slots[i] = r
                self._pos[i] = 0
                self._next_tok[i] = r.prompt[0]
                self._reset_slot_cache(i)
        if all(s is None for s in self._slots):
            return []

        tokens = jnp.asarray(self._next_tok)
        pos = jnp.asarray(self._pos)
        logits, self.caches = self._step_fn(self.params, self.caches,
                                            tokens, pos)
        if self.temperature > 0:
            self._key, k = jax.random.split(self._key)
            sampled = jax.random.categorical(k, logits / self.temperature,
                                             axis=-1)
        else:
            sampled = jnp.argmax(logits, axis=-1)
        sampled = np.asarray(sampled)

        finished = []
        for i, r in enumerate(self._slots):
            if r is None:
                continue
            consumed = int(self._pos[i]) + 1       # tokens fed so far
            self._pos[i] += 1
            if consumed < len(r.prompt):
                self._next_tok[i] = r.prompt[consumed]   # still prefilling
                continue
            tok = int(sampled[i])
            r.generated.append(tok)
            self._next_tok[i] = tok
            hit_eos = self.eos_id is not None and tok == self.eos_id
            if (len(r.generated) >= r.max_new_tokens or hit_eos
                    or int(self._pos[i]) >= self.cache_len):
                r.done = True
                finished.append(r)
                self._slots[i] = None
        return finished
