"""Resumable run manifest (the durable control plane's source of truth).

A live run with ``run_dir`` set persists a small JSON document next to
the disk replica tier, rewritten atomically at every global replication
point (docs/protocol.md §8):

* ``config`` — the full ``run.RunConfig`` serialization (workload spec,
  live/protocol settings, transport kind, wire policy), enough to rebuild
  the identical chain, batch stream, and cluster shape in a fresh
  process;
* ``state`` — what the coordinator learned while running: the last
  COMMITTED batch (the newest batch whose update every layer's disk
  replica has absorbed — a resume restarts at ``last_committed + 1``),
  the partition in force, live worker ids, per-device
  incarnations (PR 4 epoch fencing), the node -> (host, port) routing
  table for TCP runs, and the wire policy actually in force.

``last_committed`` is -1 until the first global replication lands — a
resume from such a manifest is just a fresh start. The manifest is
written via write-to-temp + fsync + ``os.replace`` + directory fsync, so
a SIGKILL mid-write leaves either the old or the new document, never a
torn one; the disk tier's index uses the same discipline, and the
manifest is written AFTER the tier's ``sync()``, so the batch it names is
always fully recoverable.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Optional

MANIFEST_NAME = "manifest.json"


def _fsync_dir(path: str) -> None:
    """fsync a directory so a just-renamed file survives power loss."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def atomic_write_json(path: str, doc: dict) -> None:
    """Crash-consistent JSON write: temp file + fsync + rename + dir fsync."""
    tmp = path + ".tmp"
    with open(tmp, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    _fsync_dir(os.path.dirname(os.path.abspath(path)))


@dataclasses.dataclass
class RunManifest:
    """One resumable run: ``config`` rebuilds the run, ``state`` says how
    far it got. Both are plain-JSON dicts (see module docstring)."""

    config: dict
    state: dict
    version: int = 1

    @property
    def last_committed(self) -> int:
        """Newest batch fully covered by the disk replica tier; -1 when
        no global replication point has committed yet."""
        return int(self.state.get("last_committed", -1))

    def to_doc(self) -> dict:
        return {"version": self.version, "config": self.config,
                "state": self.state}

    @staticmethod
    def from_doc(doc: dict) -> "RunManifest":
        if int(doc.get("version", 0)) != 1:
            raise ValueError(
                f"unsupported manifest version {doc.get('version')!r}")
        return RunManifest(config=dict(doc.get("config", {})),
                           state=dict(doc.get("state", {})),
                           version=1)

    def save(self, directory: str) -> str:
        os.makedirs(directory, exist_ok=True)
        path = os.path.join(directory, MANIFEST_NAME)
        atomic_write_json(path, self.to_doc())
        return path

    @staticmethod
    def load(directory: str) -> "RunManifest":
        path = os.path.join(directory, MANIFEST_NAME)
        with open(path, encoding="utf-8") as f:
            return RunManifest.from_doc(json.load(f))

    @staticmethod
    def try_load(directory: str) -> Optional["RunManifest"]:
        """Load if present and readable, else None (poll-friendly: a
        concurrent atomic save never yields a torn read, only old/new)."""
        try:
            return RunManifest.load(directory)
        except (OSError, ValueError):
            return None


FLEET_MANIFEST_NAME = "fleet.json"


@dataclasses.dataclass
class FleetManifest:
    """Fleet-level sibling of ``RunManifest``: one ``fleet.json`` at the
    fleet's ``run_dir`` root describing the data axis — the
    ``run.RunConfig.fleet`` block (``config``) plus the supervisor's view
    (``state``: live chains, published aggregation rounds, per-chain
    incarnation counts). Each CHAIN keeps its own full ``RunManifest``
    under ``run_dir/chain<i>/`` exactly as a single-chain run would, so
    chain-level resume machinery is untouched; fleet-level resume (replay
    this document) is future work and the version field gates it."""

    config: dict
    state: dict
    version: int = 1

    def to_doc(self) -> dict:
        return {"version": self.version, "config": self.config,
                "state": self.state}

    @staticmethod
    def from_doc(doc: dict) -> "FleetManifest":
        if int(doc.get("version", 0)) != 1:
            raise ValueError(
                f"unsupported fleet manifest version {doc.get('version')!r}")
        return FleetManifest(config=dict(doc.get("config", {})),
                             state=dict(doc.get("state", {})),
                             version=1)

    def write(self, directory: str) -> str:
        os.makedirs(directory, exist_ok=True)
        path = os.path.join(directory, FLEET_MANIFEST_NAME)
        atomic_write_json(path, self.to_doc())
        return path

    @staticmethod
    def try_load(directory: str) -> Optional["FleetManifest"]:
        try:
            path = os.path.join(directory, FLEET_MANIFEST_NAME)
            with open(path, encoding="utf-8") as f:
                return FleetManifest.from_doc(json.load(f))
        except (OSError, ValueError):
            return None
