"""Chain + global replication as a checkpointing layer (TPU-native mapping
of paper §III-E — see DESIGN.md §2).

Per-stage weight shards are replicated (a) to the next stage's slot
("chain": survives any single stage loss) and (b) to a global store
("global": survives arbitrary losses). ``recover_stage`` prefers the fresher
replica, exactly mirroring ``core.replication.ReplicaStore.recover``.
"""
from __future__ import annotations

import json
import os
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint.manifest import _fsync_dir, atomic_write_json
from repro.core.replication import chain_target, should_chain, should_global


class ReplicatedCheckpointer:
    def __init__(self, num_stages: int, chain_every: int = 50,
                 global_every: int = 100):
        self.num_stages = num_stages
        self.chain_every = chain_every
        self.global_every = global_every
        self._chain: dict[int, tuple[int, Any]] = {}
        self._global: dict[int, tuple[int, Any]] = {}

    def maybe_replicate(self, batch: int, stage_weights: Callable[[int], Any]):
        """Call once per batch; snapshots per-stage weights on schedule.
        Returns (did_chain, did_global) for cost accounting."""
        did_c = should_chain(batch, self.chain_every)
        did_g = should_global(batch, self.global_every)
        if did_c:
            for s in range(self.num_stages):
                self._chain[s] = (batch, jax.tree.map(lambda a: a,
                                                      stage_weights(s)))
        if did_g:
            for s in range(self.num_stages):
                self._global[s] = (batch, jax.tree.map(lambda a: a,
                                                       stage_weights(s)))
        return did_c, did_g

    def recover_stage(self, stage: int,
                      lost_stages: set[int]) -> Optional[tuple[int, Any, str]]:
        holder = chain_target(stage, self.num_stages)
        if stage in self._chain and holder not in lost_stages:
            b, w = self._chain[stage]
            g = self._global.get(stage)
            if g is None or g[0] <= b:
                return b, w, "chain"
        if stage in self._global:
            b, w = self._global[stage]
            return b, w, "global"
        return None

    def latest_consistent_batch(self, lost_stages: set[int]) -> int:
        """Newest batch for which EVERY stage has a recoverable replica."""
        best = -1
        for s in range(self.num_stages):
            r = self.recover_stage(s, lost_stages)
            if r is None:
                return -1
            best = r[0] if best < 0 else min(best, r[0])
        return best


def _replica_nbytes(params: Any) -> int:
    leaves = jax.tree.leaves(params)
    return sum(int(l.nbytes) for l in leaves if hasattr(l, "nbytes"))


class LayerReplicaStore:
    """LAYER-keyed replica store for the live runtime (``runtime/live.py``).
    Stage-keyed stores (above) go stale the moment the partition moves;
    keying by layer makes replicas survive dynamic re-partition (§III-D)
    and worker-list renumbering (§III-F) — the redistribution planner's
    fallback targets always resolve.

    Snapshots arrive as packed flat f32 buffers (per-layer slices of a
    stage's contiguous weight buffer, ``runtime/stage_executor``), so a
    replica is one array, its wire size is exact (``nbytes``), and serving
    a §III-F fetch is a reference hand-off, not a pytree copy. The store is
    value-agnostic: legacy pytree snapshots still work.

    Replicas live in named TIERS matching the paper's two replication
    paths: ``"chain"`` (neighbor copies, §III-E) and ``"global"`` (central
    store) — ``tier`` defaults to ``"global"`` everywhere, so single-tier
    callers never see the distinction. A layer snapshotted at the same
    batch into both tiers is ONE logical replica held twice; ``nbytes()``
    therefore reports the DEDUPED total (each distinct ``(layer, batch)``
    snapshot counted once), ``nbytes(tier)`` the exact per-tier bytes, and
    ``nbytes_report()`` both plus the duplicated remainder. The old
    behavior — summing tiers blindly — double-counted exactly those
    shared snapshots (see ``docs/protocol.md``).
    """

    CHAIN = "chain"
    GLOBAL = "global"

    def __init__(self):
        self._tiers: dict[str, dict[int, tuple[int, Any]]] = {}

    def put(self, layer: int, batch: int, params: Any,
            tier: str = GLOBAL) -> None:
        """Keep the freshest snapshot per layer within ``tier``."""
        t = self._tiers.setdefault(tier, {})
        cur = t.get(layer)
        if cur is None or batch >= cur[0]:
            t[layer] = (batch, params)

    def put_many(self, batch: int, layers: dict, tier: str = GLOBAL) -> None:
        """Absorb one replication message ({layer -> packed weights})."""
        for j, p in layers.items():
            self.put(j, batch, p, tier)

    def refresh(self, batch: int, same: dict,
                tier: str = GLOBAL) -> list[int]:
        """Delta-plus-skip COMPARE-AND-STAMP (§III-E wire compression):
        ``same`` maps layer -> the batch the sender last shipped it into
        this tier. The sender verified those bytes are still its current
        snapshot, so bump the stored batch id to ``batch`` without any
        data on the wire — but ONLY where this store's stamp equals the
        sender's claim. Transports are best-effort: if the put the sender
        remembers never arrived (or this tier holds a fresher copy from
        someone else), the stamps mismatch and the entry is left alone —
        conservatively old rather than freshly mis-labeled. Layers the
        tier does not hold are ignored (never fabricate a replica).
        Returns the layer ids actually re-stamped."""
        t = self._tiers.setdefault(tier, {})
        done = []
        for j, prev in same.items():
            cur = t.get(j)
            if cur is not None and cur[0] == prev and batch >= cur[0]:
                t[j] = (batch, cur[1])
                done.append(j)
        return done

    def nbytes(self, tier: Optional[str] = None) -> int:
        """Stored replica bytes. With ``tier``: that tier's exact footprint.
        Without: the deduped logical total — each distinct (layer, batch)
        snapshot counted once even when both tiers hold it."""
        if tier is not None:
            return sum(_replica_nbytes(p)
                       for _, p in self._tiers.get(tier, {}).values())
        seen: dict[tuple[int, int], int] = {}
        for t in self._tiers.values():
            for layer, (batch, p) in t.items():
                seen.setdefault((layer, batch), _replica_nbytes(p))
        return sum(seen.values())

    def nbytes_report(self) -> dict:
        """{"per_tier": {tier -> bytes}, "deduped": int, "duplicated": int,
        "in_memory": int, "on_disk": int} where ``duplicated`` is the bytes
        a naive sum over tiers would over-report (snapshots present in more
        than one tier). ``in_memory``/``on_disk`` split the footprint by
        medium: the base store is memory-only (``on_disk`` = 0);
        ``DurableLayerReplicaStore`` overrides ``on_disk`` with its disk
        tier's indexed file bytes."""
        per_tier = {t: self.nbytes(t) for t in self._tiers}
        deduped = self.nbytes()
        return {"per_tier": per_tier, "deduped": deduped,
                "duplicated": sum(per_tier.values()) - deduped,
                "in_memory": deduped, "on_disk": 0}

    def has(self, layer: int, tier: Optional[str] = None) -> bool:
        """Whether any tier (or the given one) holds the layer."""
        tiers = [self._tiers.get(tier, {})] if tier is not None \
            else self._tiers.values()
        return any(layer in t for t in tiers)

    def get(self, layer: int,
            tier: Optional[str] = None) -> Optional[tuple[int, Any]]:
        """Freshest (batch, params) for the layer across tiers (or within
        ``tier``); None if absent."""
        tiers = [self._tiers.get(tier, {})] if tier is not None \
            else self._tiers.values()
        best = None
        for t in tiers:
            cur = t.get(layer)
            if cur is not None and (best is None or cur[0] > best[0]):
                best = cur
        return best

    def batches(self, tier: Optional[str] = None) -> dict[int, int]:
        """layer -> batch id of its freshest stored snapshot."""
        out: dict[int, int] = {}
        tiers = [self._tiers.get(tier, {})] if tier is not None \
            else self._tiers.values()
        for t in tiers:
            for layer, (b, _) in t.items():
                if layer not in out or b > out[layer]:
                    out[layer] = b
        return out

    def covers(self, num_layers: int, tier: Optional[str] = None) -> bool:
        """Every layer 0..num_layers-1 recoverable from the store."""
        return all(self.has(l, tier) for l in range(num_layers))


class DiskLayerTier:
    """Crash-consistent on-disk tier of per-layer slice files.

    Layout (one directory)::

        layer_00003.00000016.bin   raw bytes of layer 3's packed slice,
                                   snapshotted at batch 16 (tmp+rename)
        replicas.json              the INDEX: {layer -> {batch, file,
                                   dtype, shape}}, atomically replaced

    The index is the single source of truth: ``load()`` reads only files
    it names, so a SIGKILL mid-``put`` (a ``.bin`` written but not yet
    indexed, or a dangling ``.tmp``) leaves the previous committed state
    intact and the stray file is garbage-collected at the next ``sync()``.
    ``put`` stages an entry in memory; ``sync()`` — called at global
    replication points, before the manifest is written — fsyncs the staged
    files, replaces the index (fsync + rename + directory fsync), and GCs
    orphans. A delta-skip ``restamp`` only rewrites the index entry's
    batch stamp (the bytes on disk are verified-current by the sender), so
    the file name's embedded batch is a birth label, not authoritative.

    Values must be array-like (the live runtime's packed flat f32 slices);
    legacy pytree snapshots are not durable and stay memory-only."""

    INDEX = "replicas.json"

    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(self.dir, exist_ok=True)
        self._index: dict[int, dict] = {}
        self._staged: dict[int, dict] = {}
        self._dirty = False
        path = os.path.join(self.dir, self.INDEX)
        if os.path.exists(path):
            with open(path, encoding="utf-8") as f:
                doc = json.load(f)
            self._index = {int(k): dict(v)
                           for k, v in doc.get("layers", {}).items()}

    def put(self, layer: int, batch: int, value: Any) -> None:
        arr = np.asarray(value)
        cur = self._staged.get(layer) or self._index.get(layer)
        if cur is not None and int(cur["batch"]) >= batch:
            return
        name = f"layer_{layer:05d}.{batch:08d}.bin"
        tmp = os.path.join(self.dir, name + ".tmp")
        with open(tmp, "wb") as f:
            f.write(np.ascontiguousarray(arr).tobytes())
        os.replace(tmp, os.path.join(self.dir, name))
        self._staged[layer] = {"batch": int(batch), "file": name,
                               "dtype": str(arr.dtype),
                               "shape": list(arr.shape)}
        self._dirty = True

    def restamp(self, layer: int, batch: int) -> None:
        """Delta-skip: the sender verified the stored bytes are still its
        current snapshot — advance the stamp without rewriting the file."""
        ent = self._staged.get(layer) or self._index.get(layer)
        if ent is not None and batch >= int(ent["batch"]):
            newe = dict(ent)
            newe["batch"] = int(batch)
            self._staged[layer] = newe
            self._dirty = True

    def sync(self) -> None:
        """Commit staged puts: fsync their files, atomically replace the
        index, GC unreferenced ``.bin``/``.tmp`` files."""
        if not self._dirty:
            return
        for ent in self._staged.values():
            path = os.path.join(self.dir, ent["file"])
            try:
                fd = os.open(path, os.O_RDONLY)
            except OSError:
                continue
            try:
                os.fsync(fd)
            finally:
                os.close(fd)
        self._index.update(self._staged)
        self._staged = {}
        atomic_write_json(
            os.path.join(self.dir, self.INDEX),
            {"layers": {str(k): v for k, v in self._index.items()}})
        live = {ent["file"] for ent in self._index.values()}
        for name in os.listdir(self.dir):
            if name.endswith((".bin", ".tmp")) and name not in live:
                try:
                    os.remove(os.path.join(self.dir, name))
                except OSError:
                    pass
        _fsync_dir(self.dir)
        self._dirty = False

    def load(self) -> dict[int, tuple[int, np.ndarray]]:
        """{layer -> (batch, array)} for every INDEXED snapshot; staged or
        orphaned files are invisible (they never committed)."""
        out: dict[int, tuple[int, np.ndarray]] = {}
        for layer, ent in self._index.items():
            path = os.path.join(self.dir, ent["file"])
            try:
                raw = open(path, "rb").read()
            except OSError:
                continue
            arr = np.frombuffer(raw, dtype=np.dtype(ent["dtype"]))
            out[layer] = (int(ent["batch"]),
                          arr.reshape([int(s) for s in ent["shape"]]))
        return out

    def batches(self) -> dict[int, int]:
        return {layer: int(ent["batch"])
                for layer, ent in self._index.items()}

    def nbytes(self) -> int:
        total = 0
        for ent in self._index.values():
            try:
                total += os.path.getsize(os.path.join(self.dir, ent["file"]))
            except OSError:
                pass
        return total


class DurableLayerReplicaStore(LayerReplicaStore):
    """``LayerReplicaStore`` whose GLOBAL tier is mirrored to a
    ``DiskLayerTier`` (ISSUE direction 4: the coordinator's central store
    must survive the coordinator). Construction replays the disk index
    into the in-memory GLOBAL tier, which is how a relaunched coordinator
    recovers every layer at the manifest's committed batch. Mirroring is
    write-through but commit is explicit: call ``sync()`` at replication
    points (the coordinator does, right before saving the manifest)."""

    def __init__(self, directory: str):
        super().__init__()
        self.disk = DiskLayerTier(directory)
        for layer, (batch, arr) in self.disk.load().items():
            super().put(layer, batch, arr, self.GLOBAL)

    def put(self, layer: int, batch: int, params: Any,
            tier: str = LayerReplicaStore.GLOBAL) -> None:
        super().put(layer, batch, params, tier)
        if tier == self.GLOBAL:
            try:
                self.disk.put(layer, batch, params)
            except (TypeError, ValueError):
                pass                    # non-array legacy value: memory-only

    def refresh(self, batch: int, same: dict,
                tier: str = LayerReplicaStore.GLOBAL) -> list[int]:
        done = super().refresh(batch, same, tier)
        if tier == self.GLOBAL:
            for j in done:
                self.disk.restamp(j, batch)
        return done

    def sync(self) -> None:
        self.disk.sync()

    def nbytes_report(self) -> dict:
        rep = super().nbytes_report()
        rep["on_disk"] = self.disk.nbytes()
        return rep
