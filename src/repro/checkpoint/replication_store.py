"""Chain + global replication as a checkpointing layer (TPU-native mapping
of paper §III-E — see DESIGN.md §2).

Per-stage weight shards are replicated (a) to the next stage's slot
("chain": survives any single stage loss) and (b) to a global store
("global": survives arbitrary losses). ``recover_stage`` prefers the fresher
replica, exactly mirroring ``core.replication.ReplicaStore.recover``.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax

from repro.core.replication import chain_target, should_chain, should_global


class ReplicatedCheckpointer:
    def __init__(self, num_stages: int, chain_every: int = 50,
                 global_every: int = 100):
        self.num_stages = num_stages
        self.chain_every = chain_every
        self.global_every = global_every
        self._chain: dict[int, tuple[int, Any]] = {}
        self._global: dict[int, tuple[int, Any]] = {}

    def maybe_replicate(self, batch: int, stage_weights: Callable[[int], Any]):
        """Call once per batch; snapshots per-stage weights on schedule.
        Returns (did_chain, did_global) for cost accounting."""
        did_c = should_chain(batch, self.chain_every)
        did_g = should_global(batch, self.global_every)
        if did_c:
            for s in range(self.num_stages):
                self._chain[s] = (batch, jax.tree.map(lambda a: a,
                                                      stage_weights(s)))
        if did_g:
            for s in range(self.num_stages):
                self._global[s] = (batch, jax.tree.map(lambda a: a,
                                                       stage_weights(s)))
        return did_c, did_g

    def recover_stage(self, stage: int,
                      lost_stages: set[int]) -> Optional[tuple[int, Any, str]]:
        holder = chain_target(stage, self.num_stages)
        if stage in self._chain and holder not in lost_stages:
            b, w = self._chain[stage]
            g = self._global.get(stage)
            if g is None or g[0] <= b:
                return b, w, "chain"
        if stage in self._global:
            b, w = self._global[stage]
            return b, w, "global"
        return None

    def latest_consistent_batch(self, lost_stages: set[int]) -> int:
        """Newest batch for which EVERY stage has a recoverable replica."""
        best = -1
        for s in range(self.num_stages):
            r = self.recover_stage(s, lost_stages)
            if r is None:
                return -1
            best = r[0] if best < 0 else min(best, r[0])
        return best


class LayerReplicaStore:
    """LAYER-keyed global replica store for the live runtime's central node
    (``runtime/live.py``). Stage-keyed stores (above) go stale the moment
    the partition moves; keying by layer makes global replicas survive
    dynamic re-partition (§III-D) and worker-list renumbering (§III-F) —
    the redistribution planner's central-fallback target always resolves.

    Snapshots arrive as packed flat f32 buffers (per-layer slices of a
    stage's contiguous weight buffer, ``runtime/stage_executor``), so a
    replica is one array, its wire size is exact (``nbytes``), and serving
    a §III-F fetch is a reference hand-off, not a pytree copy. The store is
    value-agnostic: legacy pytree snapshots still work.
    """

    def __init__(self):
        self._layers: dict[int, tuple[int, Any]] = {}

    def put(self, layer: int, batch: int, params: Any) -> None:
        """Keep the freshest snapshot per layer."""
        cur = self._layers.get(layer)
        if cur is None or batch >= cur[0]:
            self._layers[layer] = (batch, params)

    def put_many(self, batch: int, layers: dict) -> None:
        """Absorb one replication message ({layer -> packed weights})."""
        for j, p in layers.items():
            self.put(j, batch, p)

    def nbytes(self) -> int:
        """Total stored replica bytes (exact for packed-buffer snapshots)."""
        total = 0
        for _, p in self._layers.values():
            leaves = jax.tree.leaves(p)
            total += sum(int(l.nbytes) for l in leaves
                         if hasattr(l, "nbytes"))
        return total

    def has(self, layer: int) -> bool:
        return layer in self._layers

    def get(self, layer: int) -> Optional[tuple[int, Any]]:
        return self._layers.get(layer)

    def batches(self) -> dict[int, int]:
        """layer -> batch id of its stored snapshot."""
        return {l: b for l, (b, _) in self._layers.items()}

    def covers(self, num_layers: int) -> bool:
        return all(l in self._layers for l in range(num_layers))
