"""Chain + global replication as a checkpointing layer (TPU-native mapping
of paper §III-E — see DESIGN.md §2).

Per-stage weight shards are replicated (a) to the next stage's slot
("chain": survives any single stage loss) and (b) to a global store
("global": survives arbitrary losses). ``recover_stage`` prefers the fresher
replica, exactly mirroring ``core.replication.ReplicaStore.recover``.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax

from repro.core.replication import chain_target, should_chain, should_global


class ReplicatedCheckpointer:
    def __init__(self, num_stages: int, chain_every: int = 50,
                 global_every: int = 100):
        self.num_stages = num_stages
        self.chain_every = chain_every
        self.global_every = global_every
        self._chain: dict[int, tuple[int, Any]] = {}
        self._global: dict[int, tuple[int, Any]] = {}

    def maybe_replicate(self, batch: int, stage_weights: Callable[[int], Any]):
        """Call once per batch; snapshots per-stage weights on schedule.
        Returns (did_chain, did_global) for cost accounting."""
        did_c = should_chain(batch, self.chain_every)
        did_g = should_global(batch, self.global_every)
        if did_c:
            for s in range(self.num_stages):
                self._chain[s] = (batch, jax.tree.map(lambda a: a,
                                                      stage_weights(s)))
        if did_g:
            for s in range(self.num_stages):
                self._global[s] = (batch, jax.tree.map(lambda a: a,
                                                       stage_weights(s)))
        return did_c, did_g

    def recover_stage(self, stage: int,
                      lost_stages: set[int]) -> Optional[tuple[int, Any, str]]:
        holder = chain_target(stage, self.num_stages)
        if stage in self._chain and holder not in lost_stages:
            b, w = self._chain[stage]
            g = self._global.get(stage)
            if g is None or g[0] <= b:
                return b, w, "chain"
        if stage in self._global:
            b, w = self._global[stage]
            return b, w, "global"
        return None

    def latest_consistent_batch(self, lost_stages: set[int]) -> int:
        """Newest batch for which EVERY stage has a recoverable replica."""
        best = -1
        for s in range(self.num_stages):
            r = self.recover_stage(s, lost_stages)
            if r is None:
                return -1
            best = r[0] if best < 0 else min(best, r[0])
        return best


def _replica_nbytes(params: Any) -> int:
    leaves = jax.tree.leaves(params)
    return sum(int(l.nbytes) for l in leaves if hasattr(l, "nbytes"))


class LayerReplicaStore:
    """LAYER-keyed replica store for the live runtime (``runtime/live.py``).
    Stage-keyed stores (above) go stale the moment the partition moves;
    keying by layer makes replicas survive dynamic re-partition (§III-D)
    and worker-list renumbering (§III-F) — the redistribution planner's
    fallback targets always resolve.

    Snapshots arrive as packed flat f32 buffers (per-layer slices of a
    stage's contiguous weight buffer, ``runtime/stage_executor``), so a
    replica is one array, its wire size is exact (``nbytes``), and serving
    a §III-F fetch is a reference hand-off, not a pytree copy. The store is
    value-agnostic: legacy pytree snapshots still work.

    Replicas live in named TIERS matching the paper's two replication
    paths: ``"chain"`` (neighbor copies, §III-E) and ``"global"`` (central
    store) — ``tier`` defaults to ``"global"`` everywhere, so single-tier
    callers never see the distinction. A layer snapshotted at the same
    batch into both tiers is ONE logical replica held twice; ``nbytes()``
    therefore reports the DEDUPED total (each distinct ``(layer, batch)``
    snapshot counted once), ``nbytes(tier)`` the exact per-tier bytes, and
    ``nbytes_report()`` both plus the duplicated remainder. The old
    behavior — summing tiers blindly — double-counted exactly those
    shared snapshots (see ``docs/protocol.md``).
    """

    CHAIN = "chain"
    GLOBAL = "global"

    def __init__(self):
        self._tiers: dict[str, dict[int, tuple[int, Any]]] = {}

    def put(self, layer: int, batch: int, params: Any,
            tier: str = GLOBAL) -> None:
        """Keep the freshest snapshot per layer within ``tier``."""
        t = self._tiers.setdefault(tier, {})
        cur = t.get(layer)
        if cur is None or batch >= cur[0]:
            t[layer] = (batch, params)

    def put_many(self, batch: int, layers: dict, tier: str = GLOBAL) -> None:
        """Absorb one replication message ({layer -> packed weights})."""
        for j, p in layers.items():
            self.put(j, batch, p, tier)

    def refresh(self, batch: int, same: dict,
                tier: str = GLOBAL) -> list[int]:
        """Delta-plus-skip COMPARE-AND-STAMP (§III-E wire compression):
        ``same`` maps layer -> the batch the sender last shipped it into
        this tier. The sender verified those bytes are still its current
        snapshot, so bump the stored batch id to ``batch`` without any
        data on the wire — but ONLY where this store's stamp equals the
        sender's claim. Transports are best-effort: if the put the sender
        remembers never arrived (or this tier holds a fresher copy from
        someone else), the stamps mismatch and the entry is left alone —
        conservatively old rather than freshly mis-labeled. Layers the
        tier does not hold are ignored (never fabricate a replica).
        Returns the layer ids actually re-stamped."""
        t = self._tiers.setdefault(tier, {})
        done = []
        for j, prev in same.items():
            cur = t.get(j)
            if cur is not None and cur[0] == prev and batch >= cur[0]:
                t[j] = (batch, cur[1])
                done.append(j)
        return done

    def nbytes(self, tier: Optional[str] = None) -> int:
        """Stored replica bytes. With ``tier``: that tier's exact footprint.
        Without: the deduped logical total — each distinct (layer, batch)
        snapshot counted once even when both tiers hold it."""
        if tier is not None:
            return sum(_replica_nbytes(p)
                       for _, p in self._tiers.get(tier, {}).values())
        seen: dict[tuple[int, int], int] = {}
        for t in self._tiers.values():
            for layer, (batch, p) in t.items():
                seen.setdefault((layer, batch), _replica_nbytes(p))
        return sum(seen.values())

    def nbytes_report(self) -> dict:
        """{"per_tier": {tier -> bytes}, "deduped": int, "duplicated": int}
        where ``duplicated`` is the bytes a naive sum over tiers would
        over-report (snapshots present in more than one tier)."""
        per_tier = {t: self.nbytes(t) for t in self._tiers}
        deduped = self.nbytes()
        return {"per_tier": per_tier, "deduped": deduped,
                "duplicated": sum(per_tier.values()) - deduped}

    def has(self, layer: int, tier: Optional[str] = None) -> bool:
        """Whether any tier (or the given one) holds the layer."""
        tiers = [self._tiers.get(tier, {})] if tier is not None \
            else self._tiers.values()
        return any(layer in t for t in tiers)

    def get(self, layer: int,
            tier: Optional[str] = None) -> Optional[tuple[int, Any]]:
        """Freshest (batch, params) for the layer across tiers (or within
        ``tier``); None if absent."""
        tiers = [self._tiers.get(tier, {})] if tier is not None \
            else self._tiers.values()
        best = None
        for t in tiers:
            cur = t.get(layer)
            if cur is not None and (best is None or cur[0] > best[0]):
                best = cur
        return best

    def batches(self, tier: Optional[str] = None) -> dict[int, int]:
        """layer -> batch id of its freshest stored snapshot."""
        out: dict[int, int] = {}
        tiers = [self._tiers.get(tier, {})] if tier is not None \
            else self._tiers.values()
        for t in tiers:
            for layer, (b, _) in t.items():
                if layer not in out or b > out[layer]:
                    out[layer] = b
        return out

    def covers(self, num_layers: int, tier: Optional[str] = None) -> bool:
        """Every layer 0..num_layers-1 recoverable from the store."""
        return all(self.has(l, tier) for l in range(num_layers))
