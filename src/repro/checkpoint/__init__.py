from repro.checkpoint.store import CheckpointStore, save_pytree, restore_pytree
from repro.checkpoint.manifest import RunManifest, atomic_write_json
from repro.checkpoint.replication_store import (
    DiskLayerTier,
    DurableLayerReplicaStore,
    LayerReplicaStore,
    ReplicatedCheckpointer,
)
