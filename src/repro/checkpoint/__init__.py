from repro.checkpoint.store import CheckpointStore, save_pytree, restore_pytree
from repro.checkpoint.replication_store import ReplicatedCheckpointer
