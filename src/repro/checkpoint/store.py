"""Pytree checkpointing to disk (msgpack + raw numpy buffers).

The central node's own fault protection (paper §III-E: "saving the training
states and model weights to the disk periodically").
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_pytree(path: str, tree: Any, meta: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    leaves, treedef = _flatten(tree)
    manifest = {"treedef": str(treedef), "meta": meta or {},
                "leaves": [{"shape": list(np.shape(l)),
                            "dtype": str(np.asarray(l).dtype)} for l in leaves]}
    with open(path + ".json", "w") as f:
        json.dump(manifest, f)
    with open(path + ".bin", "wb") as f:
        for l in leaves:
            f.write(np.ascontiguousarray(np.asarray(l)).tobytes())


def restore_pytree(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shapes must match)."""
    leaves, treedef = _flatten(like)
    with open(path + ".json") as f:
        manifest = json.load(f)
    assert len(manifest["leaves"]) == len(leaves), "structure mismatch"
    out = []
    with open(path + ".bin", "rb") as f:
        for l, spec in zip(leaves, manifest["leaves"]):
            arr = np.frombuffer(
                f.read(int(np.prod(spec["shape"]) or 1)
                       * np.dtype(spec["dtype"]).itemsize),
                dtype=spec["dtype"]).reshape(spec["shape"])
            assert list(np.shape(l)) == spec["shape"], (np.shape(l), spec)
            out.append(jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, out)


class CheckpointStore:
    """Step-indexed checkpoint directory with retention."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"ckpt_{step:08d}")

    def save(self, step: int, tree: Any, meta: dict | None = None) -> str:
        p = self._path(step)
        save_pytree(p, tree, {"step": step, **(meta or {})})
        self._gc()
        return p

    def steps(self) -> list[int]:
        out = []
        for fn in os.listdir(self.directory):
            if fn.startswith("ckpt_") and fn.endswith(".json"):
                out.append(int(fn[5:13]))
        return sorted(out)

    def restore_latest(self, like: Any):
        steps = self.steps()
        if not steps:
            return None, -1
        return restore_pytree(self._path(steps[-1]), like), steps[-1]

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep]:
            for ext in (".json", ".bin"):
                try:
                    os.remove(self._path(s) + ext)
                except FileNotFoundError:
                    pass
