"""Version compatibility shims for jax APIs the repo relies on.

The pipeline engine targets current jax (top-level ``jax.shard_map``,
``jax.set_mesh``, ``jax.sharding.AxisType``), but the runtime also has to
run on older 0.4.x installs where those live elsewhere or do not exist.
Each shim resolves the modern spelling first and falls back to the legacy
one with the same semantics; ``launch/mesh.py`` hosts the mesh-construction
side of this (``axis_types_kwarg`` / ``mesh_context``).
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` where it exists, else the legacy
    ``jax.experimental.shard_map.shard_map`` (same call surface; the
    replication check is named ``check_rep`` there)."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as legacy
    return legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check_vma)


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict. Modern jax returns the
    dict directly; 0.4.x returns a one-element list of per-program dicts."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def shard_map_is_legacy() -> bool:
    """True when we fall back to ``jax.experimental.shard_map``. Its
    transpose rule mis-partitions residuals when a *secondary* output is
    param-dependent in the linearized jaxpr (raises a raw ``_SpecError``),
    so callers must keep auxiliary outputs out of the differentiated graph
    on such installs."""
    return getattr(jax, "shard_map", None) is None
