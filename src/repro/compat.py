"""Version compatibility shims for jax APIs the repo relies on.

The pipeline engine targets current jax (top-level ``jax.shard_map``,
``jax.set_mesh``, ``jax.sharding.AxisType``), but the runtime also has to
run on older 0.4.x installs where those live elsewhere or do not exist.
Each shim resolves the modern spelling first and falls back to the legacy
one with the same semantics; ``launch/mesh.py`` hosts the mesh-construction
side of this (``axis_types_kwarg`` / ``mesh_context``).
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` where it exists, else the legacy
    ``jax.experimental.shard_map.shard_map`` (same call surface; the
    replication check is named ``check_rep`` there)."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as legacy
    return legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check_vma)
