"""The public run facade: one way to construct, launch, and resume runs.

Every entry point — ``launch/live_train.py``, the examples, tests, the
failover demo — builds a :class:`RunConfig` (workload spec + live/protocol
settings + transport choice) and drives it through a :class:`Run` handle.
Nobody outside this module wires a ``LiveConfig`` to a transport by hand
anymore; the facade owns the mapping from config to cluster shape:

* ``transport="queue"`` — in-process cluster (threads + queue
  ``Transport``), the CI-friendly default;
* ``transport="tcp"``   — real OS processes over ``SocketTransport``
  (``runtime/net.py``), one per worker device.

A config with ``live.run_dir`` set is DURABLE: the coordinator mirrors
global replicas to disk and atomically rewrites a run manifest at every
global replication point (docs/protocol.md §8). ``Run.resume(run_dir)``
rebuilds the config from that manifest and relaunches from the last
committed batch — including after a coordinator SIGKILL, re-adopting
surviving worker processes through the abort+install handshake.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Optional

from repro.checkpoint.manifest import RunManifest
from repro.runtime import protocol as protocol_mod
from repro.runtime.fleet import FleetConfig
from repro.runtime.live import COORD, Coordinator, LiveConfig, LiveResult
from repro.runtime.workload import WorkloadSpec

# LiveConfig fields that do NOT round-trip through the manifest: runtime
# objects (profile, device_specs, bandwidth), fault injection (fault,
# kill, kill_all_at, rejoin, join_after, netem — a resumed run must not
# replay the crash schedule or the emulated network that produced the
# manifest), per-process knobs (interpret), and the resume coordinates
# themselves (run_dir/start_batch/resume are assigned by Run.resume,
# never persisted).
_LIVE_SKIP = frozenset({
    "protocol", "profile", "device_specs", "bandwidth", "fault", "kill",
    "rejoin", "join_after", "interpret", "run_dir", "start_batch",
    "resume", "netem", "kill_all_at",
})


def _live_to_doc(live: LiveConfig) -> dict:
    doc = {f.name: getattr(live, f.name)
           for f in dataclasses.fields(live) if f.name not in _LIVE_SKIP}
    doc["protocol"] = dataclasses.asdict(live.protocol)
    return doc


def _live_from_doc(doc: dict) -> LiveConfig:
    doc = dict(doc)
    proto = protocol_mod.ProtocolConfig(**doc.pop("protocol", {}))
    known = {f.name for f in dataclasses.fields(LiveConfig)}
    return LiveConfig(protocol=proto,
                      **{k: v for k, v in doc.items() if k in known})


# One row per CLI flag: argparse dest -> (config group, config field,
# fallback default for partial namespaces). This TABLE is the whole
# CLI-to-config mapping — adding a flag is one argparse line in
# launch/live_train.py plus one row here (tests/test_fleet.py guards the
# two against drifting apart). Flags that need more than a rename are
# finished in the explicit fixup pass inside ``from_args`` below.
_ARG_MAP = {
    # ---- workload (WorkloadSpec) ----------------------------------------
    "chain":                ("workload", "kind", "mlp"),
    "seed":                 ("workload", "seed", 0),
    "layers":               ("workload", "num_layers", 8),
    "batch_size":           ("workload", "batch_size", 16),
    "data_batches":         ("workload", "num_data_batches", None),
    # ---- protocol (ProtocolConfig) --------------------------------------
    "chain_every":          ("protocol", "chain_every", 10),
    "global_every":         ("protocol", "global_every", 20),
    "repartition_first_at": ("protocol", "repartition_first_at", 5),
    "repartition_every":    ("protocol", "repartition_every", 15),
    "detect_timeout":       ("protocol", "detect_timeout", 0.5),
    "refit_hysteresis":     ("protocol", "refit_hysteresis", None),
    # ---- live (LiveConfig) ----------------------------------------------
    "workers":              ("live", "num_workers", 3),
    "batches":              ("live", "num_batches", 40),
    "lr":                   ("live", "lr", 0.1),
    "momentum":             ("live", "momentum", 0.0),
    "aggregate_every":      ("live", "aggregate_every", 0),
    "capacity_source":      ("live", "capacity_source", "measured"),
    "emulate":              ("live", "emulate_capacity", False),
    "uncompiled":           ("live", "compiled", False),   # inverted below
    "wire_codec":           ("live", "wire_codec", False),
    "wire_compress":        ("live", "wire_compress", "off"),
    "wire_compress_replica": ("live", "wire_compress_replica", None),
    "join_wait":            ("live", "join_wait", 20.0),
    "reliable_wire":        ("live", "reliable_data", False),
    "run_dir":              ("live", "run_dir", None),
    "capacity_ema":         ("live", "capacity_ema", 0.0),
    "static_partition":     ("live", "static_partition", False),
    "overlap_replication":  ("live", "overlap_replication", False),
    "repl_delta":           ("live", "repl_delta", "counters"),
    "netem":                ("live", "netem", None),       # parsed below
    # ---- fleet (FleetConfig) --------------------------------------------
    "chains":               ("fleet", "chains", 1),
    "fleet_every":          ("fleet", "aggregate_every", 10),
    # ---- run (RunConfig itself) -----------------------------------------
    "transport":            ("run", "transport", "queue"),
    "host":                 ("run", "host", "127.0.0.1"),
}


@dataclasses.dataclass
class RunConfig:
    """Everything needed to launch (or relaunch) one training run.

    ``workload`` is the deterministic recipe every process rebuilds the
    model/data from (only tensors travel the wire); ``live`` carries the
    protocol + runtime knobs, including ``live.run_dir`` for durable
    runs; ``fleet`` adds the data axis (M replicated chains meeting at a
    periodic weight-aggregation barrier — ``runtime/fleet.py``; the
    default is a single chain, exactly the pre-fleet behavior);
    ``transport`` picks the cluster substrate."""

    workload: WorkloadSpec = dataclasses.field(default_factory=WorkloadSpec)
    live: LiveConfig = dataclasses.field(default_factory=LiveConfig)
    fleet: FleetConfig = dataclasses.field(default_factory=FleetConfig)
    transport: str = "queue"                    # "queue" | "tcp"
    host: str = "127.0.0.1"                     # tcp: bind/connect host

    def __post_init__(self):
        if self.transport not in ("queue", "tcp"):
            raise ValueError(f"unknown transport {self.transport!r}")

    # --------------------------- CLI binding -----------------------------

    @staticmethod
    def from_args(ns) -> "RunConfig":
        """Build from an argparse namespace (``launch/live_train.py``'s
        flag set, underscores for dashes) by walking ``_ARG_MAP``. Only
        attributes present on ``ns`` are consulted, so partial namespaces
        (tests, embedding CLIs) work; fallback defaults mirror the CLI's.
        Fault injection (--kill / --rejoin / --join-after) and per-host
        plumbing stay CLI-local — they are applied on top and never
        serialized to a manifest."""
        groups: dict = {"workload": {}, "protocol": {}, "live": {},
                        "fleet": {}, "run": {}}
        for dest, (group, field, default) in _ARG_MAP.items():
            groups[group][field] = getattr(ns, dest, default)
        # fixups — the few flags that are more than a rename:
        w = groups["workload"]
        if w.get("num_data_batches") is None:    # kind-dependent default
            w["num_data_batches"] = 8 if w["kind"] == "mlp" else 4
        lv = groups["live"]
        lv["compiled"] = not lv["compiled"]      # dest is --uncompiled
        if isinstance(lv.get("netem"), str):     # inline JSON or a path
            from repro.runtime.netem import NetemSpec
            lv["netem"] = NetemSpec.from_json(lv["netem"])
        proto = protocol_mod.ProtocolConfig(**groups.pop("protocol"))
        return RunConfig(workload=WorkloadSpec(**w),
                         live=LiveConfig(protocol=proto, **lv),
                         fleet=FleetConfig(**groups["fleet"]),
                         **groups["run"])

    # ------------------------ manifest round-trip ------------------------

    def to_manifest(self) -> dict:
        """The plain-JSON ``config`` block of the run manifest — enough
        for ``from_manifest`` to rebuild an equivalent RunConfig in a
        fresh process. Block version 2 = fleet-aware (version 1 docs,
        written before the ``fleet`` block existed, still load — they
        mean a single-chain run)."""
        return {"version": 2,
                "workload": dataclasses.asdict(self.workload),
                "live": _live_to_doc(self.live),
                "fleet": self.fleet.to_doc(),
                "transport": self.transport,
                "host": self.host}

    @staticmethod
    def from_manifest(doc: dict) -> "RunConfig":
        version = int(doc.get("version", 1))
        if version not in (1, 2):
            raise ValueError(
                f"unsupported run-config version {version!r}")
        return RunConfig(
            workload=WorkloadSpec(**doc.get("workload", {})),
            live=_live_from_doc(doc.get("live", {})),
            fleet=FleetConfig.from_doc(doc.get("fleet")),
            transport=doc.get("transport", "queue"),
            host=doc.get("host", "127.0.0.1"))


class Run:
    """Handle on one training run: ``start()`` launches it on a daemon
    thread, ``wait()`` joins it, ``status()`` reports progress (reading
    the manifest for durable runs), ``stop()`` asks the coordinator to
    wind down cleanly at the next batch boundary.

    ``Run.resume(run_dir)`` is the relaunch entry: it loads the manifest,
    rebuilds the config, and returns a Run that starts from the last
    committed batch, re-adopting surviving remote workers (TCP runs)
    instead of spawning a cold cluster."""

    def __init__(self, config: RunConfig,
                 addr_of: Optional[dict] = None):
        """``addr_of`` (tcp only): attach to an EXISTING cluster at these
        node -> (host, port) addresses — multi-host ``--role coordinator``
        mode, where worker processes are started per-host by the operator
        — instead of spawning localhost worker processes."""
        self.config = config
        self.addr_of = addr_of
        self._thread: Optional[threading.Thread] = None
        self._coord: Optional[Coordinator] = None
        self._fleet = None               # FleetCoordinator (chains > 1)
        self._result = None              # LiveResult | FleetResult
        self._error: Optional[BaseException] = None
        self._resume_state: Optional[dict] = None
        self._stop_wanted = False
        self._lock = threading.Lock()

    # ------------------------------ resume -------------------------------

    @staticmethod
    def resume(run_dir: str, num_batches: Optional[int] = None) -> "Run":
        """Relaunch the run persisted under ``run_dir`` from its last
        committed batch. A manifest with ``last_committed = -1`` (crashed
        before the first global replication) resumes as a fresh start.
        ``num_batches`` overrides the recorded horizon (e.g. to extend a
        finished run)."""
        manifest = RunManifest.load(run_dir)
        config = RunConfig.from_manifest(manifest.config)
        start = manifest.last_committed + 1
        live = dataclasses.replace(
            config.live, run_dir=run_dir, resume=start > 0,
            start_batch=max(start, 0),
            num_batches=(num_batches if num_batches is not None
                         else int(manifest.state.get(
                             "num_batches", config.live.num_batches))))
        run = Run(dataclasses.replace(config, live=live))
        if start > 0:
            run._resume_state = dict(manifest.state)
        return run

    # ----------------------------- lifecycle -----------------------------

    def start(self) -> "Run":
        with self._lock:
            if self._thread is not None:
                raise RuntimeError("run already started")
            self._thread = threading.Thread(
                target=self._main, name="run-facade", daemon=True)
            self._thread.start()
        return self

    def wait(self, timeout: Optional[float] = None):
        """Join the run. Returns a ``LiveResult`` for single-chain runs,
        a ``fleet.FleetResult`` when ``config.fleet.chains > 1``."""
        if self._thread is None:
            raise RuntimeError("run not started")
        self._thread.join(timeout)
        if self._thread.is_alive():
            raise TimeoutError("run still in progress")
        if self._error is not None:
            raise self._error
        assert self._result is not None
        return self._result

    def stop(self) -> None:
        """Request a clean wind-down at the next batch boundary (durable
        runs keep their manifest; ``wait()`` still returns a result). Safe
        to call before the coordinator exists — the request is applied the
        moment the cluster wiring hands us one."""
        with self._lock:
            self._stop_wanted = True
            coord = self._coord
            fleet = self._fleet
        if fleet is not None:
            fleet.request_stop()
        elif coord is not None:
            coord.request_stop()

    def _attach(self, coord: Coordinator) -> None:
        with self._lock:
            self._coord = coord
            wanted = self._stop_wanted
        if wanted:
            coord.request_stop()

    def status(self) -> dict:
        """Progress snapshot, in the nested fleet/chains schema
        (docs/operations.md):

            {"state", "transport",
             "fleet":  {chains, live, rounds, aggregate_every, ...},
             "chains": {chain_id: {"progress", "wire", "membership"}}}

        A single-chain run is reported as a fleet of one (its chain id is
        0). For durable runs the manifest's last committed batch rides in
        ``chains[i]["progress"]["last_committed_manifest"]`` (readable by
        ANY process, not just the owning one).

        DEPRECATED top-level aliases — ``batches_done``, ``wire``,
        ``last_committed`` — mirror chain 0 / the fleet max for one
        release; read the nested schema instead."""
        if self._thread is None:
            state = "created"
        elif self._thread.is_alive():
            state = "running"
        else:
            state = "failed" if self._error is not None else "finished"
        with self._lock:
            coord, fleet = self._coord, self._fleet
        out = {"state": state, "transport": self.config.transport}
        if fleet is not None:
            snap = fleet.status()
        elif coord is not None:
            snap = {"fleet": {"chains": 1, "live": [0],
                              "aggregate_every": 0, "rounds": 0,
                              "incarnations": {0: 1}},
                    "chains": {0: coord.chain_status()}}
        else:
            snap = {"fleet": {"chains": self.config.fleet.chains,
                              "live": [], "rounds": 0,
                              "aggregate_every":
                              self.config.fleet.aggregate_every,
                              "incarnations": {}},
                    "chains": {}}
        out["fleet"] = snap["fleet"]
        out["chains"] = snap["chains"]
        run_dir = self.config.live.run_dir
        if run_dir and self.config.fleet.chains == 1:
            manifest = RunManifest.try_load(run_dir)
            if 0 in out["chains"]:
                out["chains"][0]["progress"]["last_committed_manifest"] = (
                    manifest.last_committed if manifest is not None else -1)
        # ---- deprecated flat aliases (one release; docs/operations.md) --
        out["batches_done"] = max(
            (c["progress"]["batches_done"] for c in out["chains"].values()),
            default=0)
        wire0 = out["chains"].get(0, {}).get("wire")
        if wire0 is not None:
            out["wire"] = {"bytes": wire0.get("bytes", 0),
                           "kind_bytes": dict(wire0.get("kind_bytes", {})),
                           "kind_msgs": dict(wire0.get("kind_msgs", {}))}
        if run_dir and self.config.fleet.chains == 1:
            manifest = RunManifest.try_load(run_dir)
            out["last_committed"] = (manifest.last_committed
                                     if manifest is not None else -1)
        if self._error is not None:
            out["error"] = repr(self._error)
        return out

    # --------------------------- cluster wiring --------------------------

    def _main(self) -> None:
        try:
            self._result = self._run_impl()
        except BaseException as exc:          # surfaced by wait()
            self._error = exc

    def _run_impl(self):
        cfg = self.config
        if cfg.fleet.chains > 1:
            if self._resume_state is not None:
                raise RuntimeError(
                    "fleet resume is not supported yet — resume each "
                    "chain's run_dir/chain<i> individually")
            if self.addr_of is not None:
                raise RuntimeError(
                    "fleet runs manage their own clusters; --role "
                    "attachment is single-chain only")
            return self._run_fleet(cfg)
        if cfg.transport == "queue":
            return self._run_queue(cfg)
        if self._resume_state is not None:
            return self._run_tcp_resume(cfg)
        if self.addr_of is not None:
            return self._run_tcp_attached(cfg, self.addr_of)
        return self._run_tcp_fresh(cfg)

    def _run_fleet(self, cfg: RunConfig):
        from repro.runtime.fleet import FleetCoordinator
        fc = FleetCoordinator(cfg.workload, cfg.live, cfg.fleet,
                              transport=cfg.transport, host=cfg.host,
                              run_dir=cfg.live.run_dir)
        with self._lock:
            self._fleet = fc
            wanted = self._stop_wanted
        if wanted:
            fc.request_stop()
        return fc.run()

    def _run_queue(self, cfg: RunConfig) -> LiveResult:
        chain, batches = cfg.workload.build()
        coord = Coordinator(chain, lambda b: batches[b % len(batches)],
                            cfg.live, manifest_doc=cfg.to_manifest(),
                            resume_state=self._resume_state)
        self._attach(coord)
        return coord.run()

    def _run_tcp_fresh(self, cfg: RunConfig) -> LiveResult:
        from repro.runtime import net

        def grab(coord):
            self._attach(coord)

        return net.run_tcp_training(cfg.workload, cfg.live, host=cfg.host,
                                    manifest_doc=cfg.to_manifest(),
                                    on_coordinator=grab)

    def _run_tcp_attached(self, cfg: RunConfig, addr_of: dict) -> LiveResult:
        """Coordinator attached to operator-managed worker processes
        (multi-host clusters): bind our address from ``addr_of``, expect
        every other device to announce itself."""
        from repro.runtime.net import SocketTransport

        chain, batches = cfg.workload.build()
        transport = SocketTransport(addr_of, local=(COORD, 0),
                                    fault=cfg.live.fault,
                                    policy=cfg.live.wire_policy(),
                                    reliable=cfg.live.reliable_data,
                                    rto=cfg.live.rto,
                                    netem=cfg.live.netem)
        coord = Coordinator(chain, lambda b: batches[b % len(batches)],
                            cfg.live, transport=transport,
                            remote_devs={d for d in addr_of if d > 0},
                            manifest_doc=cfg.to_manifest())
        self._attach(coord)
        try:
            return coord.run()
        finally:
            transport.close()

    def _run_tcp_resume(self, cfg: RunConfig) -> LiveResult:
        """Relaunched TCP coordinator: rebind the manifest's recorded
        coordinator address, re-adopt surviving worker PROCESSES (they
        were never ours to spawn — they outlived the old coordinator),
        and train the remaining batches. Workers that died with the old
        coordinator are dropped from the partition at bring-up."""
        from repro.runtime.net import SocketTransport

        state = self._resume_state or {}
        addr_of = {int(n): (a[0], int(a[1]))
                   for n, a in state.get("addr_of", {}).items()}
        if COORD not in addr_of:
            raise RuntimeError("manifest has no coordinator address — "
                               "was this a queue run?")
        chain, batches = cfg.workload.build()
        transport = SocketTransport(addr_of, local=(COORD, 0),
                                    policy=cfg.live.wire_policy(),
                                    reliable=cfg.live.reliable_data,
                                    rto=cfg.live.rto,
                                    netem=cfg.live.netem)
        remote = {int(d) for d in state.get("worker_ids", []) if int(d) > 0}
        coord = Coordinator(chain, lambda b: batches[b % len(batches)],
                            cfg.live, transport=transport,
                            remote_devs=remote,
                            manifest_doc=cfg.to_manifest(),
                            resume_state=state)
        self._attach(coord)
        try:
            return coord.run()
        finally:
            transport.close()


def start_run(config: RunConfig) -> Run:
    """Convenience: ``Run(config).start()``."""
    return Run(config).start()
