"""LR schedules. The paper drops the LR at epoch 130 of 300 (Fig. 4)."""
from __future__ import annotations

import jax.numpy as jnp


def step_decay(base_lr: float, boundaries=(130,), factor: float = 0.1):
    def lr(epoch):
        e = jnp.asarray(epoch)
        k = sum((e >= b).astype(jnp.int32) for b in boundaries)
        return base_lr * factor ** k
    return lr


def warmup_cosine(base_lr: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        s = jnp.asarray(step, jnp.float32)
        warm = base_lr * jnp.minimum(1.0, s / max(warmup, 1))
        t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(s < warmup, warm, base_lr * cos)
    return lr
