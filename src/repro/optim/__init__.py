from repro.optim.sgd import sgd_init, sgd_update
from repro.optim.adam import adam_init, adam_update
from repro.optim.schedules import step_decay, warmup_cosine

OPTIMIZERS = {"sgd": (sgd_init, sgd_update), "adam": (adam_init, adam_update)}


def get_optimizer(name: str):
    return OPTIMIZERS[name]
