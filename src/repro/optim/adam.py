"""AdamW (for the LM examples; the paper itself uses SGD)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adam_init(params):
    z = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": z, "v": jax.tree.map(jnp.copy, z),
            "count": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                weight_decay=0.0, momentum=None):
    c = state["count"] + 1
    cf = c.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mh = m_new / (1 - b1 ** cf)
        vh = v_new / (1 - b2 ** cf)
        step = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m_new, v_new

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
    return pick(0), {"m": pick(1), "v": pick(2), "count": c}
