"""SGD + momentum + weight decay — the paper's optimizer (§IV-B: momentum
0.9, weight decay 4e-5). Pure pytree transform; the Pallas fused variant
(kernels/fused_sgd) implements the same update for flat parameter tiles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sgd_init(params):
    return {"momentum": jax.tree.map(jnp.zeros_like, params)}


def sgd_update(params, grads, state, *, lr, momentum=0.9, weight_decay=4e-5):
    def upd(p, g, m):
        g = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
        m_new = momentum * m.astype(jnp.float32) + g
        p_new = p.astype(jnp.float32) - lr * m_new
        return p_new.astype(p.dtype), m_new.astype(m.dtype)

    flat = jax.tree.map(upd, params, grads, state["momentum"])
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"momentum": new_m}
