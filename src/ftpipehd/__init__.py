"""``ftpipehd`` — the paper-named alias for the ``repro`` package.

The reproduction grew under ``repro.*``; this thin package gives the
public surface its paper name without moving code. ``ftpipehd.run`` is
the supported entry point (RunConfig / Run / start_run)."""
import sys

from repro import run

# make ``from ftpipehd.run import Run`` work: the alias must be a real
# importable submodule, not just an attribute of this package
sys.modules[__name__ + ".run"] = run

__all__ = ["run"]
