#!/usr/bin/env python3
"""Docs consistency checker (stdlib only; run by the CI docs job).

Three invariants over README.md and docs/**/*.md:

1. every intra-repo markdown link ``[text](path)`` resolves to a real
   file or directory (fragments are stripped; http/mailto skipped);
2. every ``--flag`` mentioned in the prose exists in some argparse CLI of
   this repo — and when the surrounding line names a specific CLI
   (``live_train``, a ``benchmarks/*.py`` or ``examples/*.py`` path),
   the flag must exist in THAT file's parser;
3. every backticked CODE PATH (a `` `dir/file.ext` `` token with a slash,
   e.g. ``runtime/codec.py`` or ``../src/repro/runtime/codec.py``)
   resolves to a real file — relative to the doc, the repo root, or the
   ``src/repro`` package — so refactors can't silently orphan the spec's
   prose references the way they can't orphan its links.

Flags are discovered by scanning ``add_argument("--...")`` calls, so the
check needs no imports of repo code (and no JAX).

    python tools/check_docs.py          # exits non-zero on any violation
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FLAG_RE = re.compile(r"(?<![-\w])(--[a-z][a-z0-9-]*)\b")
ADD_ARG_RE = re.compile(r"add_argument\(\s*[\"'](--[A-Za-z0-9-]+)[\"']")
# backticked path-like tokens: at least one '/', a known code/doc
# extension, no spaces — `runtime/codec.py`, `../src/.../net.py`, ...
CODE_REF_RE = re.compile(
    r"`([A-Za-z0-9_.\-]+(?:/[A-Za-z0-9_.\-]+)+"
    r"\.(?:py|json|md|yml|yaml|toml))`")

# flags that belong to tools outside this repo, not to our CLIs
EXTERNAL_FLAGS = {"--help"}

# code-ref roots tried after the doc's own dir: the repo root and the
# package dir (docs prose uses package-relative names like
# `runtime/live.py` for src/repro/runtime/live.py)
CODE_REF_ROOTS = (".", "src", "src/repro")

# substring of a doc line -> the CLI source file it refers to
CLI_HINTS = {
    "live_train": "src/repro/launch/live_train.py",
    "bench_live_throughput.py": "benchmarks/bench_live_throughput.py",
    "bench_fault_recovery.py": "benchmarks/bench_fault_recovery.py",
    "bench_replication.py": "benchmarks/bench_replication.py",
    "bench_dynamic_partition.py": "benchmarks/bench_dynamic_partition.py",
    "live_fault_tolerance.py": "examples/live_fault_tolerance.py",
    "live_tcp_fault_tolerance.py": "examples/live_tcp_fault_tolerance.py",
    "live_elastic_rejoin.py": "examples/live_elastic_rejoin.py",
    "live_compressed_wire.py": "examples/live_compressed_wire.py",
    "live_coordinator_failover.py": "examples/live_coordinator_failover.py",
    "fault_tolerance_demo.py": "examples/fault_tolerance_demo.py",
    "bench_wan_validation.py": "benchmarks/bench_wan_validation.py",
    "check_bench.py": "tools/check_bench.py",
}


def md_files() -> list[Path]:
    files = [REPO / "README.md"]
    files += sorted((REPO / "docs").rglob("*.md"))
    return [f for f in files if f.exists()]


def flags_of(py_path: Path) -> set[str]:
    try:
        return set(ADD_ARG_RE.findall(py_path.read_text(encoding="utf-8")))
    except OSError:
        return set()


def all_repo_flags() -> set[str]:
    flags: set[str] = set()
    for sub in ("src", "benchmarks", "examples", "tools"):
        for py in (REPO / sub).rglob("*.py"):
            flags |= flags_of(py)
    return flags


def check_links(md: Path) -> list[str]:
    errors = []
    for target in LINK_RE.findall(md.read_text(encoding="utf-8")):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        resolved = (md.parent / path).resolve()
        if not resolved.exists():
            errors.append(f"{md.relative_to(REPO)}: broken link -> {target}")
    return errors


def check_code_refs(md: Path) -> list[str]:
    """Invariant 3: backticked code paths resolve to real files."""
    errors = []
    for lineno, line in enumerate(
            md.read_text(encoding="utf-8").splitlines(), 1):
        for ref in CODE_REF_RE.findall(line):
            bases = [md.parent] + [REPO / r for r in CODE_REF_ROOTS]
            if not any((b / ref).resolve().exists() for b in bases):
                errors.append(f"{md.relative_to(REPO)}:{lineno}: code "
                              f"reference `{ref}` resolves to no file "
                              f"(tried doc dir, repo root, src, src/repro)")
    return errors


def check_flags(md: Path, union: set[str]) -> list[str]:
    errors = []
    for lineno, line in enumerate(
            md.read_text(encoding="utf-8").splitlines(), 1):
        found = [f for f in FLAG_RE.findall(line)
                 if f not in EXTERNAL_FLAGS]
        if not found:
            continue
        scoped = [cli for hint, cli in CLI_HINTS.items() if hint in line]
        for flag in found:
            if scoped:
                ok = any(flag in flags_of(REPO / cli) for cli in scoped)
                where = " or ".join(scoped)
            else:
                ok = flag in union
                where = "any repo CLI"
            if not ok:
                errors.append(f"{md.relative_to(REPO)}:{lineno}: "
                              f"flag {flag} not defined in {where}")
    return errors


def main() -> int:
    union = all_repo_flags()
    if not union:
        print("check_docs: found no argparse flags at all — "
              "is the repo layout intact?")
        return 2
    errors: list[str] = []
    files = md_files()
    for md in files:
        errors += check_links(md)
        errors += check_code_refs(md)
        errors += check_flags(md, union)
    if errors:
        print(f"check_docs: {len(errors)} problem(s):")
        for e in errors:
            print("  " + e)
        return 1
    print(f"check_docs: OK — {len(files)} markdown files, "
          f"{len(union)} known CLI flags")
    return 0


if __name__ == "__main__":
    sys.exit(main())
