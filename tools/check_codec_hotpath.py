#!/usr/bin/env python3
"""Codec hot-path lint (stdlib only; run by the CI docs/lint job).

The whole point of the device-quantized wire tier (codec tag 13,
``docs/protocol.md`` §1b) is that ``encode``/``decode`` never touch numpy
for those frames: the tensor is already u8 codes + per-channel params
(quantized INSIDE the compiled stage step by ``kernels/quant``), so the
codec's job is pure struct packing and byte slicing — zero-copy
passthrough. A numpy call creeping into that path would silently
reintroduce the per-send array pass this tier exists to delete.

This lint parses ``src/repro/runtime/codec.py`` and fails if any ``np.``
reference appears inside the quantized-tag hot functions
(``_enc_qd`` / ``_dec_qd``). It is AST-based (not a text grep) so
comments and docstrings mentioning numpy stay legal, and it fails too if
a hot function disappears — a rename must update this check, not dodge
it.

    python tools/check_codec_hotpath.py             # exits non-zero on hit
    python tools/check_codec_hotpath.py --file F    # lint another file
"""
from __future__ import annotations

import argparse
import ast
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
CODEC = REPO / "src" / "repro" / "runtime" / "codec.py"

#: functions that frame / unframe device-quantized tensors — the
#: zero-copy hot path that must stay numpy-free
HOT_FUNCS = ("_enc_qd", "_dec_qd")

#: module aliases that count as "numpy reached the hot path"
BANNED_NAMES = ("np", "numpy")


def find_violations(source: str, filename: str = "<codec>") -> list[str]:
    """Return one message per banned reference inside a hot function
    (empty list = clean). A hot function missing from the source is
    itself a violation — silently skipping would hollow the lint."""
    tree = ast.parse(source, filename=filename)
    seen = set()
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if node.name not in HOT_FUNCS:
            continue
        seen.add(node.name)
        for sub in ast.walk(node):
            if isinstance(sub, ast.Name) and sub.id in BANNED_NAMES:
                out.append(
                    f"{filename}:{sub.lineno}: numpy reference "
                    f"`{sub.id}` inside {node.name}() — the quantized-tag "
                    f"wire path must stay zero-copy (struct packing and "
                    f"byte slicing only)")
    for name in HOT_FUNCS:
        if name not in seen:
            out.append(
                f"{filename}: hot function {name}() not found — if it was "
                f"renamed, update HOT_FUNCS in tools/check_codec_hotpath.py "
                f"so the zero-copy lint follows it")
    return out


def main() -> int:
    ap = argparse.ArgumentParser(
        description="Fail if numpy appears in the codec's device-quantized "
                    "(zero-copy) encode/decode path")
    ap.add_argument("--file", default=str(CODEC),
                    help="python source to lint (default: the repo codec)")
    args = ap.parse_args()
    path = Path(args.file)
    try:
        source = path.read_text()
    except OSError as e:
        print(f"check_codec_hotpath: cannot read {path}: {e}")
        return 2
    violations = find_violations(source, str(path))
    if violations:
        print(f"check_codec_hotpath: {len(violations)} violation(s):")
        for msg in violations:
            print("  " + msg)
        return 1
    print(f"check_codec_hotpath: OK — {', '.join(HOT_FUNCS)} in {path} "
          f"are numpy-free")
    return 0


if __name__ == "__main__":
    sys.exit(main())
