#!/usr/bin/env python3
"""Perf-regression gate (stdlib only; run by the CI smoke job).

Compares a freshly measured ``bench_live_throughput.py`` result against
the committed baseline ``BENCH_live_throughput.json`` and fails when any
gated metric regressed by more than ``--max-regression`` (default 30%).

Gated metrics (higher-is-better):

  * ``compiled_speedup``   — fused jitted StageExecutor vs eager path
  * ``wire_MBps_queue``    — in-process queue + codec throughput
  * ``wire_MBps_tcp``      — localhost TCP socket throughput
  * ``wire_compress_ratio_int8`` — f32/int8 data-plane bytes per message
  * ``live_compress_ratio_int8`` — f32/int8 wire bytes per training batch

Gated metrics (lower-is-better — the bytes-per-batch gate):

  * ``live_bytes_per_batch_int8`` — absolute int8 wire bytes per training
    batch on the live run; growing it past the band means the compressed
    wire regressed even if the f32/int8 ratio held (e.g. both sides grew)
  * ``live_bytes_per_batch_int8_fused`` — same budget for the fused
    on-device tier (``kernels/quant`` + zero-copy tag-13 frames)

Relative gates (within the current results, no baseline needed):

  * ``wire_MBps_tcp_reliable >= 0.7 * wire_MBps_tcp`` — the seq/ack
    retransmit window must not tax lossless TCP throughput by more than
    30% (skipped for result JSONs that predate the metric)
  * ``wire_msgs_per_s_tcp_int8_fused >= 0.9 * wire_msgs_per_s_tcp`` —
    the fused tier's encode is pure struct packing, so it must keep pace
    with the uncompressed wire in messages per second (skipped likewise)

WAN-validation mode (``--wan FILE``, mutually exclusive with the
baseline comparison): absolute gates on a fresh
``bench_wan_validation.py`` result, machine-independent by construction
(a fidelity RATIO against the configured LinkSpec, and a dynamic-vs-
static speedup where both sides ran on the same box in the same
process), so there is no committed baseline to drift:

  * ``wan_fidelity_min >= 0.8`` — measured latency AND token-bucket rate
    on BOTH transports within 20% of the configured LinkSpec
  * ``wan_static_batch_ms >= 1.5 * wan_dynamic_batch_ms`` — the paper's
    headline: dynamic partition beats the static equal split by >= 1.5x
    per steady-state batch on the heterogeneous trio under shaped links
  * ``wan_drain_batch_ms >= 1.2 * wan_overlap_batch_ms`` — overlapped
    replication (snapshot at the control point, ship during compute)
    beats drain-mode replication by >= 1.2x per steady-state batch

Unlike the relative gates below, a metric missing from a --wan result is
a FAILURE: the WAN gates are this benchmark's entire reason to run.

Fleet-scaling mode (``--fleet FILE``, likewise baseline-free): gates a
fresh ``bench_fleet.py`` result — the 2-chain data-parallel fleet must
reach >= 1.5x the single chain's samples/s on the same box, and must
have crossed the weight-aggregation barrier at least once while doing
it. A metric missing from a --fleet result is a FAILURE.

Usage (what CI runs)::

    python benchmarks/bench_live_throughput.py --quick --out bench_current.json
    python tools/check_bench.py --baseline BENCH_live_throughput.json \
        --current bench_current.json

    python benchmarks/bench_wan_validation.py --quick --out wan_current.json
    python tools/check_bench.py --wan wan_current.json

    python benchmarks/bench_fleet.py --quick --out fleet_current.json
    python tools/check_bench.py --fleet fleet_current.json

If the regression is REAL and intended (e.g. a correctness fix that costs
throughput), refresh the baseline locally and commit it::

    python benchmarks/bench_live_throughput.py --quick
    git add BENCH_live_throughput.json

Caveat: the gated numbers are machine-dependent (absolute MB/s, and a
JIT-vs-eager ratio that varies with core count). The 30% default band
absorbs normal runner jitter, but a baseline measured on very different
hardware than CI's runners will trip the gate on the FIRST run — the fix
is the same refresh flow above, run once from that environment.
"""
from __future__ import annotations

import argparse
import json
import sys

# metric -> short meaning (higher-is-better; noisy wall-clock metrics
# like recovery_s_* are NOT gated — recovery time on shared CI runners is
# too noisy to gate without flaking)
GATED_METRICS = {
    "compiled_speedup": "compiled/uncompiled hot-path speedup",
    "wire_MBps_queue": "queue transport wire throughput",
    "wire_MBps_tcp": "TCP transport wire throughput",
    "wire_compress_ratio_int8": "f32/int8 data-plane compression (TCP)",
    "live_compress_ratio_int8": "f32/int8 wire bytes per training batch",
}

# metric -> short meaning (LOWER-is-better: absolute byte budgets — the
# bytes-per-batch gate next to the MB/s ones)
GATED_METRICS_LOWER = {
    "live_bytes_per_batch_int8": "int8 wire bytes per training batch",
    "live_bytes_per_batch_int8_fused":
        "fused on-device int8 wire bytes per training batch",
}

# relative gates WITHIN the current results: (numerator, denominator,
# min ratio, meaning). Machine-independent by construction — both sides
# come from the same run on the same box — so no baseline is consulted.
# A numerator missing from current is SKIPPED (older result JSONs predate
# the metric), unlike the baseline-gated metrics above.
RELATIVE_GATES = [
    ("wire_MBps_tcp_reliable", "wire_MBps_tcp", 0.70,
     "seq/ack retransmit window overhead on the lossless TCP wire"),
    ("wire_msgs_per_s_tcp_int8_fused", "wire_msgs_per_s_tcp", 0.90,
     "fused int8 tier (zero-copy tag-13 encode) vs plain TCP msgs/s"),
]


def compare(baseline: dict, current: dict,
            max_regression: float = 0.30) -> list[str]:
    """Failure messages for every gated metric that regressed past the
    threshold (empty list = gate passes). A metric missing from either
    side is itself a failure — silently skipping would hollow the gate."""
    failures = []
    for key, meaning in list(GATED_METRICS.items()) \
            + list(GATED_METRICS_LOWER.items()):
        if key not in baseline:
            failures.append(f"{key}: missing from baseline (re-generate "
                            f"BENCH_live_throughput.json)")
            continue
        if key not in current:
            failures.append(f"{key}: missing from current results "
                            f"(did the benchmark run to completion?)")
            continue
        base, cur = float(baseline[key]), float(current[key])
        if key in GATED_METRICS_LOWER:
            ceiling = (1.0 + max_regression) * base
            if cur > ceiling:
                failures.append(
                    f"{key} ({meaning}): {cur:.0f} vs baseline {base:.0f} "
                    f"— {100 * (cur / base - 1):.0f}% growth "
                    f"(> {100 * max_regression:.0f}% allowed)")
            continue
        floor = (1.0 - max_regression) * base
        if cur < floor:
            failures.append(
                f"{key} ({meaning}): {cur:.2f} vs baseline {base:.2f} "
                f"— {100 * (1 - cur / base):.0f}% regression "
                f"(> {100 * max_regression:.0f}% allowed)")
    for num, den, min_ratio, meaning in RELATIVE_GATES:
        if num not in current:
            continue                   # result JSON predates the metric
        if den not in current:
            failures.append(f"{den}: missing from current results but "
                            f"{num} is present — truncated benchmark?")
            continue
        ratio = float(current[num]) / max(float(current[den]), 1e-12)
        if ratio < min_ratio:
            failures.append(
                f"{num} ({meaning}): {float(current[num]):.2f} is only "
                f"{ratio:.2f}x of {den} {float(current[den]):.2f} "
                f"(floor {min_ratio:.2f}x)")
    return failures


# WAN gates: (numerator, denominator-or-None, min value/ratio, meaning).
# With a denominator the gate is num/den >= floor; without, num >= floor.
# All machine-independent (ratios within one run / against the configured
# spec) — no baseline, no refresh flow. Missing metric = FAILURE.
WAN_GATES = [
    ("wan_fidelity_min", None, 0.80,
     "worst shaper fidelity (latency+rate, queue+tcp) vs LinkSpec"),
    ("wan_static_batch_ms", "wan_dynamic_batch_ms", 1.50,
     "dynamic-partition speedup over static equal split under WAN links"),
    ("wan_drain_batch_ms", "wan_overlap_batch_ms", 1.20,
     "overlapped-replication speedup over drain mode under WAN links"),
]


def check_wan(current: dict) -> list[str]:
    """Failure messages for the WAN-validation gates (empty = pass)."""
    failures = []
    for num, den, floor, meaning in WAN_GATES:
        missing = [k for k in (num, den) if k and k not in current]
        if missing:
            failures.append(
                f"{'/'.join(missing)}: missing from results — the WAN "
                f"benchmark did not run to completion")
            continue
        if den is None:
            val = float(current[num])
            if val < floor:
                failures.append(f"{num} ({meaning}): {val:.3f} "
                                f"< floor {floor:.2f}")
            continue
        ratio = float(current[num]) / max(float(current[den]), 1e-12)
        if ratio < floor:
            failures.append(
                f"{num}/{den} ({meaning}): {float(current[num]):.1f} / "
                f"{float(current[den]):.1f} = {ratio:.2f}x "
                f"< floor {floor:.2f}x")
    return failures


# Fleet gates: same shape as WAN_GATES. Machine-independent by
# construction (the 1-chain and 2-chain fleets ran on the same box in the
# same process, with the same sleep-emulated device speeds), so there is
# no committed baseline. Missing metric = FAILURE.
FLEET_GATES = [
    ("fleet_samples_per_s_2chain", "fleet_samples_per_s_1chain", 1.50,
     "2-chain data-parallel fleet throughput over a single chain"),
    ("fleet_rounds_2chain", None, 1.0,
     "the 2-chain run must cross the aggregation barrier at least once "
     "(otherwise the speedup is measured without the fleet's sync cost)"),
]


def check_fleet(current: dict) -> list[str]:
    """Failure messages for the fleet-scaling gates (empty = pass)."""
    failures = []
    for num, den, floor, meaning in FLEET_GATES:
        missing = [k for k in (num, den) if k and k not in current]
        if missing:
            failures.append(
                f"{'/'.join(missing)}: missing from results — the fleet "
                f"benchmark did not run to completion")
            continue
        if den is None:
            val = float(current[num])
            if val < floor:
                failures.append(f"{num} ({meaning}): {val:.3f} "
                                f"< floor {floor:.2f}")
            continue
        ratio = float(current[num]) / max(float(current[den]), 1e-12)
        if ratio < floor:
            failures.append(
                f"{num}/{den} ({meaning}): {float(current[num]):.1f} / "
                f"{float(current[den]):.1f} = {ratio:.2f}x "
                f"< floor {floor:.2f}x")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(
        description="Fail on live-throughput perf regressions vs the "
                    "committed baseline")
    ap.add_argument("--baseline", default="BENCH_live_throughput.json",
                    help="committed baseline JSON")
    ap.add_argument("--current",
                    help="freshly measured JSON "
                         "(bench_live_throughput.py --out ...)")
    ap.add_argument("--max-regression", type=float, default=0.30,
                    help="allowed fractional drop per metric (default "
                         "0.30 = 30%%)")
    ap.add_argument("--wan", metavar="FILE",
                    help="gate a bench_wan_validation.py result instead "
                         "(absolute gates, no baseline)")
    ap.add_argument("--fleet", metavar="FILE",
                    help="gate a bench_fleet.py result instead "
                         "(relative gates within one run, no baseline)")
    args = ap.parse_args()

    if args.fleet:
        try:
            with open(args.fleet) as f:
                current = json.load(f)
        except (OSError, ValueError) as e:
            print(f"check_bench: cannot read fleet results "
                  f"{args.fleet}: {e}")
            return 2
        failures = check_fleet(current)
        if failures:
            print(f"check_bench: {len(failures)} fleet gate failure(s):")
            for msg in failures:
                print("  " + msg)
            return 1
        speedup = (float(current["fleet_samples_per_s_2chain"])
                   / float(current["fleet_samples_per_s_1chain"]))
        print(f"check_bench: fleet OK — 2-chain speedup {speedup:.2f}x "
              f"(floor 1.50x) across "
              f"{int(current['fleet_rounds_2chain'])} aggregation "
              f"round(s)")
        return 0

    if args.wan:
        try:
            with open(args.wan) as f:
                current = json.load(f)
        except (OSError, ValueError) as e:
            print(f"check_bench: cannot read WAN results {args.wan}: {e}")
            return 2
        failures = check_wan(current)
        if failures:
            print(f"check_bench: {len(failures)} WAN gate failure(s):")
            for msg in failures:
                print("  " + msg)
            return 1
        speedup = (float(current["wan_static_batch_ms"])
                   / float(current["wan_dynamic_batch_ms"]))
        ov = (float(current["wan_drain_batch_ms"])
              / float(current["wan_overlap_batch_ms"]))
        print(f"check_bench: WAN OK — fidelity_min="
              f"{float(current['wan_fidelity_min']):.3f} (floor 0.80), "
              f"dynamic speedup {speedup:.2f}x (floor 1.50x), "
              f"overlap speedup {ov:.2f}x (floor 1.20x)")
        return 0

    if not args.current:
        ap.error("--current is required (or use --wan FILE)")

    try:
        with open(args.baseline) as f:
            baseline = json.load(f)
    except (OSError, ValueError) as e:
        print(f"check_bench: cannot read baseline {args.baseline}: {e}")
        return 2
    try:
        with open(args.current) as f:
            current = json.load(f)
    except (OSError, ValueError) as e:
        print(f"check_bench: cannot read current {args.current}: {e}")
        return 2

    failures = compare(baseline, current, args.max_regression)
    if failures:
        print(f"check_bench: {len(failures)} perf regression(s) vs "
              f"{args.baseline}:")
        for msg in failures:
            print("  " + msg)
        print()
        print("If this regression is intended, refresh the baseline and "
              "commit it:")
        print("    python benchmarks/bench_live_throughput.py --quick")
        print("    git add BENCH_live_throughput.json")
        print("If the baseline was measured on different hardware than "
              "CI's runners, download the bench-live-throughput artifact "
              "from this run and commit THAT as the baseline instead.")
        return 1
    hi = ", ".join(f"{k}={float(current[k]) / float(baseline[k]):.2f}x"
                   for k in GATED_METRICS)
    lo = ", ".join(f"{k}={float(current[k]) / float(baseline[k]):.2f}x"
                   for k in GATED_METRICS_LOWER)
    print(f"check_bench: OK — current vs baseline: {hi} "
          f"(gate: >= {1 - args.max_regression:.2f}x); "
          f"bytes-per-batch: {lo} "
          f"(lower is better; gate: <= {1 + args.max_regression:.2f}x)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
